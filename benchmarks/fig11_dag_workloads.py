"""Fig. 11: DAG-structured workloads — layer-precedence scheduling.

The DAG axis (``repro.core.dag``) generalizes ``ModelPlan`` from a
linear layer chain to a precedence DAG: a multi-branch model's
independent chains (an ASR encoder/decoder split, a two-branch VLM's
vision and text towers, a 4-expert MoE's parallel experts) surface as
concurrently-ready nodes of ONE request, placed independently on
different accelerators.  Algorithm 1 distributes the deadline over the
*critical path* instead of the chain sum, so parallel branches get
overlapping budgets, and Terastal's Eq. 8 slack test follows the
binding successor (the fan-in node the branch feeds).

Measures the DAG_SCENARIOS catalog x schedulers, reporting miss rate,
accuracy loss, and variant engagement.  Three gates ride along: on the
pinned encoder/decoder fan-in cell Terastal must beat BOTH FCFS and EDF
by >= MIN_SEPARATION_PTS miss-rate points (precedence-aware placement
is the PR's headline deliverable); every pre-PR linear-chain catalog
cell must reproduce its pre-PR fingerprint bit-identically on both
engines (the DAG machinery is strictly additive); and reference-vs-SoA
must stay fingerprint-identical on the DAG cells themselves.  A fourth
claim asserts the parallelism is real, not incidental: two sibling
nodes of one request observed in flight simultaneously on different
accelerators.

Writes ``BENCH_dag.json``.  CI runs ``--smoke`` via run.py, then
``--check-json`` as a dedicated step that FAILS on any of the claims.
"""

from __future__ import annotations

import argparse
import json
import math
import os
from typing import List, Optional, Tuple

import numpy as np

#: miss-rate separation floor (percentage points) on the gate cell:
#: terastal vs EACH of fcfs and edf, enforced by claims() and the CI
#: gate even in --smoke mode.
MIN_SEPARATION_PTS = 5.0

#: the (scenario, platform) fan-in cell the separation claim is gated on.
GATE_CELL = ("dag_asr_encdec", "6k_1ws2os")

#: the baselines terastal must beat on the gate cell.
GATE_BASELINES = ("fcfs", "edf")

SCHEDULERS = ("terastal", "edf", "dream", "fcfs")

#: DAG cells are light (2-3 models each); the horizon stays fixed and
#: smoke shrinks the grid (gate scenario only, 1 seed) instead.
DURATION = 2.0

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
JSON_PATH = os.path.join(_ROOT, "BENCH_dag.json")


def _nan_to_none(x: Optional[float]) -> Optional[float]:
    if x is None or (isinstance(x, float) and math.isnan(x)):
        return None
    return float(x)


# ------------------------------------------------------------- grids ----


def _campaign_rows(scenarios, duration, seeds,
                   schedulers=SCHEDULERS) -> List[dict]:
    from repro.core import Campaign

    camp = Campaign(
        scenarios=tuple(scenarios),
        platforms=(GATE_CELL[1],),
        schedulers=tuple(schedulers),
        seeds=tuple(seeds),
        duration=duration,
    )
    result = camp.run()
    rows = []
    for (sc, sched), ts in result.grouped(("scenario", "scheduler")).items():
        miss = float(np.mean([t.mean_miss_rate for t in ts]))
        acc = [t.mean_accuracy_loss for t in ts
               if not math.isnan(t.mean_accuracy_loss)]
        rows.append({
            "scenario": sc,
            "platform": GATE_CELL[1],
            "scheduler": sched,
            "miss_rate_pct": 100 * miss,
            "acc_loss_pct": _nan_to_none(
                100 * float(np.mean(acc)) if acc else float("nan")),
            "variants_applied": sum(t.variants_applied for t in ts),
            "released": sum(t.released for t in ts),
            "completed": sum(t.completed for t in ts),
            "dropped": sum(t.dropped for t in ts),
            "seeds": len(ts),
        })
    return rows


def _separation(rows: List[dict]) -> Tuple[Optional[float], dict]:
    """min over baselines of (baseline miss - terastal miss) on the gate
    cell — the claim needs terastal to beat BOTH fcfs and edf."""
    mine = {r["scheduler"]: r for r in rows
            if r["scenario"] == GATE_CELL[0]}
    tera = mine.get("terastal")
    if tera is None or any(b not in mine for b in GATE_BASELINES):
        return None, {}
    seps = {b: mine[b]["miss_rate_pct"] - tera["miss_rate_pct"]
            for b in GATE_BASELINES}
    return min(seps.values()), {
        "terastal_miss_pct": tera["miss_rate_pct"],
        **{f"{b}_miss_pct": mine[b]["miss_rate_pct"] for b in GATE_BASELINES},
        "per_baseline_pts": seps,
    }


# ------------------------------------------- linear-chain identity ------


def _linear_identity() -> Tuple[int, bool, Optional[str]]:
    """Re-simulate every pre-PR pinned linear-chain cell (paper grid,
    saturation, overload, faults) with the DAG machinery in place and
    demand the exact pre-PR fingerprints on both engines."""
    import sys

    sys.path.insert(0, os.path.join(_ROOT, "tests"))
    from data_pre_pr9_fingerprints import PRE_PR9_FINGERPRINTS

    from repro.core import get_scenario, make_scheduler, simulate
    from repro.costmodel.maestro import PLATFORMS

    n = 0
    for key, want in sorted(PRE_PR9_FINGERPRINTS.items()):
        scenario, platform, arrival, duration, sched, adm, engine = key
        sc = get_scenario(scenario)
        plans, tasks = sc.plans(
            PLATFORMS[platform],
            arrival=None if arrival == "scenario" else arrival,
        )
        res = simulate(plans, tasks, duration, make_scheduler(sched),
                       seed=0, processes=[t.arrival for t in tasks],
                       admission=None if adm == "none" else adm,
                       faults=sc.faults, engine=engine)
        n += 1
        if res.fingerprint() != want:
            return n, False, f"{scenario}/{sched}/{adm}/{engine}"
    return n, True, None


# ------------------------------------------------------ differential ----


def _differential(smoke: bool) -> Tuple[int, bool, Optional[str]]:
    """Reference vs SoA fingerprints on the DAG catalog cells across
    schedulers and arrival processes."""
    from repro.core import get_scenario, make_scheduler, simulate
    from repro.core.workload import DAG_SCENARIOS
    from repro.costmodel.maestro import PLATFORMS

    cells = ([GATE_CELL] if smoke else
             [(name, pn) for name in sorted(DAG_SCENARIOS)
              for pn in DAG_SCENARIOS[name].platform_names])
    arrivals = (None, "mmpp(burstiness=4)") if smoke else (
        None, "poisson", "mmpp(burstiness=4)")
    n = 0
    for scenario, platform in cells:
        sc = get_scenario(scenario)
        for arrival in arrivals:
            plans, tasks = sc.plans(PLATFORMS[platform], arrival=arrival)
            procs = [t.arrival for t in tasks]
            for sched in SCHEDULERS:
                fps = []
                for engine in ("reference", "soa"):
                    res = simulate(plans, tasks, 0.3, make_scheduler(sched),
                                   seed=0, processes=procs, engine=engine)
                    fps.append(res.fingerprint())
                n += 1
                if fps[0] != fps[1]:
                    return n, False, f"{scenario}/{sched}/{arrival}"
    return n, True, None


# ----------------------------------------------- parallelism witness ----


def _parallelism_witness() -> dict:
    """Count sibling-node overlaps: pairs of nodes of ONE request in
    flight simultaneously on different accelerators (recorded through a
    delegating scheduler replicating the engine's dispatch filters)."""
    from repro.core import get_scenario, make_scheduler, simulate
    from repro.core.scheduler import Scheduler
    from repro.costmodel.maestro import PLATFORMS

    class Recorder(Scheduler):
        def __init__(self, inner):
            self.inner = inner
            self.name = inner.name
            self.uses_variants = inner.uses_variants
            self.records = []

        def schedule(self, view):
            out = self.inner.schedule(view)
            remaining = list(view.ready)
            busy = view.acc_busy_until.copy()
            for a in out:
                if a.req not in remaining or busy[a.acc] > view.now + 1e-15:
                    continue
                remaining.remove(a.req)
                plan = view.plans[a.req.model_idx]
                c = (float(plan.lat_var[a.layer, a.acc]) if a.use_variant
                     else float(plan.lat[a.layer, a.acc]))
                busy[a.acc] = view.now + c
                self.records.append(
                    (view.now, view.now + c, a.acc, a.req.dag))
            return out

    sc = get_scenario("dag_moe_4expert")
    plans, tasks = sc.plans(PLATFORMS[GATE_CELL[1]])
    rec = Recorder(make_scheduler("terastal"))
    simulate(plans, tasks, 0.5, rec, seed=0,
             processes=[t.arrival for t in tasks], engine="reference")
    by_run: dict = {}
    for r in rec.records:
        if r[3] is not None:
            by_run.setdefault(id(r[3]), []).append(r)
    overlaps = 0
    for recs in by_run.values():
        for i in range(len(recs)):
            for j in range(i + 1, len(recs)):
                (s1, f1, a1, _), (s2, f2, a2, _) = recs[i], recs[j]
                if a1 != a2 and s1 < f2 - 1e-15 and s2 < f1 - 1e-15:
                    overlaps += 1
    return {
        "cell": ["dag_moe_4expert", GATE_CELL[1]],
        "scheduler": "terastal",
        "dag_requests_dispatched": len(by_run),
        "overlapping_sibling_pairs": overlaps,
    }


# --------------------------------------------------------------- run ----


def run(duration: float = None, seeds=(0, 1, 2)) -> List[dict]:
    from benchmarks._scale import bench_mode

    mode = bench_mode()
    smoke = mode == "smoke"
    duration = duration or DURATION
    if mode != "full":
        seeds = (0,) if smoke else (0, 1)
    from repro.core.workload import DAG_SCENARIOS

    scenarios = ((GATE_CELL[0],) if smoke else tuple(sorted(DAG_SCENARIOS)))
    schedulers = (("terastal",) + GATE_BASELINES) if smoke else SCHEDULERS
    rows = _campaign_rows(scenarios, duration, seeds, schedulers)

    sep, sep_detail = _separation(rows)
    n_pins, lin_ok, lin_where = _linear_identity()
    n_diff, identical, where = _differential(smoke)
    witness = _parallelism_witness()

    summary = {
        "benchmark": "dag_workloads",
        "mode": mode,
        "grid": {
            "dag_scenarios": list(scenarios),
            "platform": GATE_CELL[1],
            "schedulers": list(schedulers),
            "duration": duration,
            "seeds": list(seeds),
        },
        "rows": rows,
        "separation": {
            "cell": list(GATE_CELL),
            "baselines": list(GATE_BASELINES),
            "separation_pts": sep,
            "min_enforced_pts": MIN_SEPARATION_PTS,
            **sep_detail,
        },
        "linear_identity": {"simulations": n_pins, "bit_identical": lin_ok,
                            "first_mismatch": lin_where},
        "differential": {"simulations": n_diff, "bit_identical": identical,
                         "first_mismatch": where},
        "parallelism": witness,
    }
    with open(JSON_PATH, "w") as f:
        json.dump(summary, f, indent=2, allow_nan=False)
        f.write("\n")
    return rows + [{
        "separation_pts": sep,
        "linear_identical": lin_ok,
        "linear_simulations": n_pins,
        "linear_first_mismatch": lin_where,
        "bit_identical": identical,
        "differential_simulations": n_diff,
        "first_mismatch": where,
        "overlapping_sibling_pairs": witness["overlapping_sibling_pairs"],
        "json": JSON_PATH,
    }]


def claims(rows: List[dict]):
    tail = rows[-1]
    grid = rows[:-1]
    sep = tail["separation_pts"]
    variants_ok = any(
        r["variants_applied"] > 0 for r in grid
        if r["scenario"] == GATE_CELL[0] and r["scheduler"] == "terastal"
    )
    return [
        (f"terastal beats fcfs AND edf on the {GATE_CELL[0]} fan-in cell "
         f"by >= {MIN_SEPARATION_PTS} miss-rate points",
         sep is not None and sep >= MIN_SEPARATION_PTS,
         f"min separation={sep:.1f} pts" if sep is not None
         else "no separation measured"),
        ("every pre-PR linear-chain cell is bit-identical to its pre-PR "
         "fingerprint (both engines)",
         bool(tail["linear_identical"]),
         f"{tail['linear_simulations']} pinned cells reproduced"
         + ("" if tail["linear_identical"]
            else f"; first mismatch {tail.get('linear_first_mismatch')}")),
        ("SimResults bit-identical: reference vs SoA on the DAG catalog "
         "(schedulers x arrival processes)",
         bool(tail["bit_identical"]),
         f"{tail['differential_simulations']} simulations compared"
         + ("" if tail["bit_identical"]
            else f"; first mismatch {tail.get('first_mismatch')}")),
        ("intra-request parallelism is real: sibling nodes of one request "
         "observed overlapping on different accelerators, and variants "
         "engage on the gate cell",
         tail["overlapping_sibling_pairs"] > 0 and variants_ok,
         f"{tail['overlapping_sibling_pairs']} overlapping sibling pairs"),
    ]


def check_json(path: str = JSON_PATH):
    """Apply the claims to an already-written BENCH_dag.json (the one
    run.py --smoke just produced) without re-measuring — the CI gate."""
    with open(path) as f:
        summary = json.load(f)
    tail = {
        "separation_pts": summary["separation"]["separation_pts"],
        "linear_identical": summary["linear_identity"]["bit_identical"],
        "linear_simulations": summary["linear_identity"]["simulations"],
        "linear_first_mismatch": summary["linear_identity"].get("first_mismatch"),
        "bit_identical": summary["differential"]["bit_identical"],
        "differential_simulations": summary["differential"]["simulations"],
        "first_mismatch": summary["differential"].get("first_mismatch"),
        "overlapping_sibling_pairs":
            summary["parallelism"]["overlapping_sibling_pairs"],
    }
    return claims(summary["rows"] + [tail])


if __name__ == "__main__":
    import sys

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small grid; unlike run.py --smoke, the separation "
                    "floor and both bit-identity gates still FAIL the "
                    "process (the CI regression gate)")
    ap.add_argument("--check-json", action="store_true",
                    help="validate the claims against the existing "
                    f"{os.path.basename(JSON_PATH)} instead of re-measuring")
    args = ap.parse_args()
    if args.smoke:
        os.environ["REPRO_BENCH_FAST"] = "1"
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    sys.path.insert(0, _ROOT)  # make the `benchmarks` package importable
    if args.check_json:
        checks = check_json()
    else:
        out = run()
        for r in out:
            print(json.dumps(r))
        checks = claims(out)
    n_ok = 0
    for name, ok, detail in checks:
        print(f"[{'PASS' if ok else 'FAIL'}] {name} ({detail})")
        n_ok += bool(ok)
    if n_ok < len(checks):
        sys.exit(1)

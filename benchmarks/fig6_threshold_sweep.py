"""Fig. 6: deadline miss rate + normalized accuracy loss vs accuracy
threshold theta, Multi-Camera Vision (Light), both 4K hardware settings.

The theta sweep is one campaign grid (theta is a first-class campaign
dimension); trials run in parallel and per-seed results match the seed's
serial loop exactly.
"""

from __future__ import annotations

from typing import List

import numpy as np

from repro.core import Campaign

THETAS = (0.80, 0.85, 0.90, 0.95, 1.00)


def run(duration: float = None, seeds=(0, 1)) -> List[dict]:
    from benchmarks._scale import bench_duration, bench_mode

    duration = bench_duration(duration, smoke=0.5, fast=2.0, full=5.0)
    if bench_mode() != "full":
        seeds = (0,)
    camp = Campaign(
        scenarios=("multicam_light",),  # platforms=None -> its 4K pairings
        schedulers=("terastal",),
        thetas=THETAS,
        seeds=tuple(seeds),
        duration=duration,
    )
    result = camp.run()
    rows = []
    for (pn, theta), ts in result.grouped(("platform", "theta")).items():
        miss = [t.mean_miss_rate for t in ts]
        acc = [t.mean_accuracy_loss for t in ts]
        rows.append({
            "platform": pn,
            "theta": theta,
            "miss_rate_pct": 100 * float(np.mean(miss)),
            "acc_loss_pct": 100 * float(np.mean(acc)),
        })
    return rows


def claims(rows: List[dict]):
    out = []
    for pn in sorted({r["platform"] for r in rows}):
        sub = sorted([r for r in rows if r["platform"] == pn], key=lambda r: r["theta"])
        misses = [r["miss_rate_pct"] for r in sub]
        accs = [r["acc_loss_pct"] for r in sub]
        # lower theta -> no higher miss rate (weak monotonicity)
        mono_miss = all(a <= b + 3.0 for a, b in zip(misses, misses[1:]))
        # accuracy loss within 1 - theta always
        within = all(r["acc_loss_pct"] <= 100 * (1 - r["theta"]) + 1e-6 for r in sub)
        out.append((f"{pn}: miss rate non-increasing as theta loosens", mono_miss, f"{np.round(misses,1)}"))
        out.append((f"{pn}: accuracy loss within threshold", within, f"{np.round(accs,2)}"))
    # the 1-WS setting benefits more from variants (gap narrows at low theta)
    p1 = sorted([r for r in rows if r["platform"] == "4k_1ws2os"], key=lambda r: r["theta"])
    p2 = sorted([r for r in rows if r["platform"] == "4k_1os2ws"], key=lambda r: r["theta"])
    if p1 and p2:
        gap_tight = abs(p1[-1]["miss_rate_pct"] - p2[-1]["miss_rate_pct"])
        gap_loose = abs(p1[0]["miss_rate_pct"] - p2[0]["miss_rate_pct"])
        out.append(("miss-rate gap between HW settings narrows as theta loosens",
                    gap_loose <= gap_tight + 3.0, f"gap@1.0={gap_tight:.1f} gap@0.8={gap_loose:.1f}"))
    return out

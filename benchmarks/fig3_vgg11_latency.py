"""Fig. 3: per-layer VGG11 latency on WS vs OS + variant effect (top),
and per-variant accuracy loss (bottom)."""

from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.core.accuracy import layer_variant_loss
from repro.core.variants import build_model_plan
from repro.costmodel.dnn_zoo import vgg11
from repro.costmodel.layers import LayerKind, make_variant, variant_feasible
from repro.costmodel.maestro import PLATFORMS, Dataflow, layer_latency


def run(fps: float = 30.0, platform: str = "6k_1ws2os") -> List[dict]:
    plat = PLATFORMS[platform]
    model = vgg11(384)
    ws = next(a for a in plat.accelerators if a.dataflow == Dataflow.WS)
    osa = next(a for a in plat.accelerators if a.dataflow == Dataflow.OS)
    plan = build_model_plan(model, plat, deadline=1.0 / fps)
    rows = []
    for idx, spec in enumerate(model.layers):
        if spec.kind not in (LayerKind.CONV,):
            continue
        l_ws = layer_latency(spec, ws, plat)
        l_os = layer_latency(spec, osa, plat)
        row = {
            "layer": spec.name,
            "ws_us": l_ws * 1e6,
            "os_us": l_os * 1e6,
            "os_over_ws": l_os / l_ws,
        }
        if idx in plan.variants:
            v = plan.variants[idx]
            k_os = [k for k, a in enumerate(plat.accelerators) if a.dataflow == Dataflow.OS][0]
            row["variant_gamma"] = v.gamma
            row["variant_os_us"] = float(v.latencies[k_os]) * 1e6
            row["variant_acc_loss_pct"] = 100 * v.loss
        rows.append(row)
    return rows


def claims(rows: List[dict]) -> List[Tuple[str, bool, str]]:
    late = [r for r in rows if r["layer"] >= "conv6"]
    ratios = [r["os_over_ws"] for r in late]
    c1 = all(r >= 2.0 for r in ratios)
    var_rows = [r for r in rows if "variant_os_us" in r]
    c2 = all(r["variant_os_us"] <= r["ws_us"] * 1.05 for r in var_rows) and var_rows
    losses = [r["variant_acc_loss_pct"] for r in var_rows]
    c3 = bool(losses) and min(losses) >= 5.0 and max(losses) <= 20.0
    return [
        ("late layers 2-8x slower on OS", c1, f"ratios={np.round(ratios,1)}"),
        ("variant OS latency <= preferred WS", bool(c2), f"{len(var_rows)} variants"),
        ("per-variant loss in ~7-17% band", c3, f"losses={np.round(losses,1)}"),
    ]

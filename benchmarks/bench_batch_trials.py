"""Device-resident mega-batched trials: trials/sec vs the campaign path.

``engine="batch"`` (core/engine_batch.py) runs B seed-replicates of one
campaign cell as ONE jitted, vmapped device program — one host sync per
batch instead of one per event — and is fingerprint-identical to the SoA
engine on every axis it supports.  This benchmark measures what that
buys in campaign trials/sec against the existing executor path
(``TrialExecutor.map``, the process-pool campaign path, which degrades
to serial in-process trials at 1 core) and against bare serial
``run_trial`` calls, at equal core count.

Methodology — the part that matters: process-level timing noise on this
workload is +-40% (a JAX-heavy process slows unrelated numpy loops),
while within-process interleaved timing is +-6%.  So both paths are
warmed in THIS process (compile + plan caches out of the timed region)
and then interleaved rep-by-rep; every number below is a median over
interleaved reps.  Numbers from separate processes are not comparable
and earlier ad-hoc measurements that did so were wrong.

Honest scope — where batching wins and where it loses: the batched
engine pads every lane to the max event horizon and carries the FULL
release set as device state, while the SoA engine early-drops hopeless
requests and caps its live backlog (~100 ready layers at saturation).
Short-horizon high-rate cells (saturation_5x at 0.1-0.125s horizons)
fit the padded state in cache and win 1.3-1.45x; longer horizons or
lower rates (saturation_3x, or 0.15s+ horizons that cross the next
event-bucket rung) lose 0.6-0.9x because batch state grows with total
arrivals but SoA work does not.  The sweep below includes both regimes
on purpose; the committed full-mode JSON is the honest scorecard.

Writes ``BENCH_batch.json``.  CI runs --smoke via run.py (informational
claims) and then ``--check-json`` as a dedicated FAILING gate:
fingerprint parity vs SoA on the pinned differential cells must hold,
and the headline cell must clear the trials/sec floor vs serial python.
The beats-the-pool claim is enforced on full-mode runs (the committed
artifact), since at smoke scale on arbitrary-core CI hosts the pool's
parallelism makes that comparison machine-dependent noise.
"""

from __future__ import annotations

import argparse
import json
import os
import statistics
import time
from typing import Dict, List

#: headline floor vs bare serial run_trial, enforced in every mode (the
#: CI smoke gate): catches engine-level throughput regressions without
#: depending on the host's core count.
MIN_SERIAL_SPEEDUP = 0.8

#: headline floor vs the campaign executor path at equal core count,
#: enforced on full-mode runs (the committed measurement).
MIN_POOL_SPEEDUP = 1.05

#: seed-replicate batch width (acceptance: B >= 32).
B = 32

#: the sweep: (scenario, platform, scheduler, arrival, duration).  First
#: row is the headline; the last two are the documented shortfall regime
#: (lower rate / longer horizon), kept in the JSON on purpose.
CELLS = (
    ("saturation_5x", "4k_1ws2os", "terastal", "poisson", 0.1),
    ("saturation_5x", "4k_1ws2os", "terastal", "periodic", 0.125),
    ("saturation_5x", "4k_1ws2os", "terastal", "poisson", 0.125),
    ("saturation_3x", "4k_1ws2os", "terastal", "poisson", 0.125),
    ("saturation_5x", "4k_1ws2os", "terastal", "poisson", 0.15),
)

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
JSON_PATH = os.path.join(_ROOT, "BENCH_batch.json")


def _specs(cell, engine: str) -> List:
    from repro.core.campaign import TrialSpec

    sc, pn, sched, arr, dur = cell
    return [TrialSpec(sc, pn, sched, arrival=arr, duration=dur, seed=s,
                      engine=engine) for s in range(B)]


def _measure_cell(cell, ex, reps: int, with_serial: bool) -> Dict[str, float]:
    """Interleaved same-process medians: trials/sec per path."""
    from repro.core.campaign import run_trial, run_trial_batch

    batch_specs = _specs(cell, "batch")
    soa_specs = _specs(cell, "soa")
    paths = {
        "batch": lambda: run_trial_batch(batch_specs),
        "pool": lambda: ex.map(soa_specs),
    }
    if with_serial:
        paths["serial"] = lambda: [run_trial(s) for s in soa_specs]
    for fn in paths.values():  # warm: compile, plan caches, pool spin-up
        fn()
    walls: Dict[str, List[float]] = {name: [] for name in paths}
    for _ in range(reps):
        for name, fn in paths.items():  # interleaved, never back-to-back
            t0 = time.perf_counter()
            fn()
            walls[name].append(time.perf_counter() - t0)
    return {name: B / statistics.median(w) for name, w in walls.items()}


# ------------------------------------------------- fingerprint parity ----


def _differential(small: bool):
    """SimResult.fingerprint() equality, every batched lane vs SoA, on
    the pinned differential cells (schedulers x arrivals x inert budget
    axes) — the bench-side twin of tests/test_engine_batch.py."""
    from repro.core.campaign import _plans_for
    from repro.core.engine_batch import simulate_batch
    from repro.core.scheduler import make_scheduler
    from repro.core.simulator import make_arrival_process, simulate

    seeds = [0, 1] if small else [0, 1, 2]
    dur = 0.1 if small else 0.12
    scheds = ["fcfs", "terastal"] if small else [
        "fcfs", "edf", "dream", "terastal", "terastal(backfill_mode=paper)"]
    grid = [("saturation_3x", "4k_1ws2os", s, a, None)
            for s in scheds for a in ("poisson", "periodic")]
    # inert budget axes must stay exact too (batch rejects online ones)
    grid.append(("saturation_3x", "4k_1ws2os", "terastal", "poisson",
                 dict(budget_policy="static", admission="none")))
    checked = 0
    for sc, pn, sched, arr, extra in grid:
        plans, tasks = _plans_for(sc, pn, 0.90, True)
        proc = make_arrival_process(arr)
        procs = [t.arrival or proc for t in tasks]
        kw = extra or {}
        batch = simulate_batch(plans, tasks, dur, make_scheduler(sched),
                               seeds, processes=procs, **kw)
        for seed, res in zip(seeds, batch):
            ref = simulate(plans, tasks, dur, make_scheduler(sched),
                           seed=seed, processes=procs, engine="soa", **kw)
            if res.fingerprint() != ref.fingerprint():
                return checked, False, f"{sc}/{sched}/{arr}/seed={seed}"
            checked += 1
    return checked, True, ""


# --------------------------------------------------------------- run ----


def run() -> List[dict]:
    from benchmarks._scale import bench_mode
    from repro.core.campaign import TrialExecutor, _plans_for

    mode = bench_mode()
    smoke = mode == "smoke"
    cells = CELLS[:1] if smoke else CELLS
    reps = {"smoke": 2, "fast": 3}.get(mode, 4)

    for sc, pn, _, _, _ in cells:  # plans out of the timed region
        _plans_for(sc, pn, 0.90, True)
    ex = TrialExecutor(parallel=True)  # equal core count: all of them
    n_workers = ex.max_workers if ex.parallel else 1

    rows = []
    try:
        for i, cell in enumerate(cells):
            tps = _measure_cell(cell, ex, reps, with_serial=(i == 0))
            sc, pn, sched, arr, dur = cell
            row = {
                "cell": f"{sc}/{pn}/{sched}/{arr}",
                "duration": dur,
                "b": B,
                "trials_per_s_batch": round(tps["batch"], 2),
                "trials_per_s_pool": round(tps["pool"], 2),
                "speedup_vs_pool": round(tps["batch"] / tps["pool"], 2),
            }
            if "serial" in tps:
                row["trials_per_s_serial"] = round(tps["serial"], 2)
                row["speedup_vs_serial"] = round(
                    tps["batch"] / tps["serial"], 2)
            rows.append(row)
    finally:
        ex.close()

    n_diff, identical, where = _differential(small=(mode != "full"))

    summary = {
        "benchmark": "batch_trials",
        "mode": mode,
        "b": B,
        "n_workers": n_workers,
        "methodology": "interleaved same-process reps, median trials/sec "
                       f"({reps} reps/path, warmed)",
        "cells": rows,
        "headline": rows[0],
        "parity": {"simulations": n_diff, "bit_identical": identical,
                   "first_mismatch": where},
        "min_serial_speedup_enforced": MIN_SERIAL_SPEEDUP,
        "min_pool_speedup_enforced_full": MIN_POOL_SPEEDUP,
        "honest_scope": "wins on short-horizon high-rate saturation cells "
                        "(padded lane state stays cache-sized); loses where "
                        "SoA's early-drop caps its backlog far below the "
                        "batch horizon width (saturation_3x, 0.15s+ "
                        "horizons) — see module docstring",
    }
    with open(JSON_PATH, "w") as f:
        json.dump(summary, f, indent=2)
        f.write("\n")
    return rows + [{"mode": mode, "headline": rows[0],
                    "bit_identical": identical, "parity_simulations": n_diff,
                    "first_mismatch": where, "json": JSON_PATH}]


def claims(rows: List[dict]):
    tail = rows[-1]
    head = tail["headline"]
    full = tail["mode"] == "full"
    pool_ok = head["speedup_vs_pool"] >= MIN_POOL_SPEEDUP if full else True
    return [
        ("batched lanes fingerprint-identical to SoA on the pinned "
         "differential cells",
         bool(tail["bit_identical"]),
         f"{tail['parity_simulations']} lanes compared"
         + ("" if tail["bit_identical"]
            else f"; first mismatch {tail['first_mismatch']}")),
        (f"headline cell >= {MIN_SERIAL_SPEEDUP}x serial python trials/sec "
         "(core-count independent floor)",
         head.get("speedup_vs_serial", 0) >= MIN_SERIAL_SPEEDUP,
         f"{head['cell']} dur={head['duration']} B={head['b']}: "
         f"{head.get('trials_per_s_serial')} -> "
         f"{head['trials_per_s_batch']} trials/s "
         f"= {head.get('speedup_vs_serial')}x"),
        (f"headline cell beats the campaign executor path by >= "
         f"{MIN_POOL_SPEEDUP}x at equal core count"
         + ("" if full else " [full-mode only; informational at this scale]"),
         pool_ok,
         f"{head['trials_per_s_pool']} -> {head['trials_per_s_batch']} "
         f"trials/s = {head['speedup_vs_pool']}x"),
    ]


def check_json(path: str = JSON_PATH):
    """Apply the parity/floor claims to an already-written
    BENCH_batch.json (the one run.py --smoke just produced) without
    re-measuring — the CI gate step."""
    with open(path) as f:
        summary = json.load(f)
    tail = {
        "mode": summary["mode"],
        "headline": summary["headline"],
        "bit_identical": summary["parity"]["bit_identical"],
        "parity_simulations": summary["parity"]["simulations"],
        "first_mismatch": summary["parity"].get("first_mismatch"),
    }
    return claims([tail])


if __name__ == "__main__":
    import sys

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="headline cell only, 2 reps; unlike run.py "
                    "--smoke, parity and the serial floor still FAIL the "
                    "process (the CI regression gate)")
    ap.add_argument("--check-json", action="store_true",
                    help="validate the claims against the existing "
                    f"{os.path.basename(JSON_PATH)} instead of re-measuring")
    args = ap.parse_args()
    if args.smoke:
        os.environ["REPRO_BENCH_FAST"] = "1"
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    sys.path.insert(0, _ROOT)  # make the `benchmarks` package importable
    if args.check_json:
        checks = check_json()
    else:
        out = run()
        for r in out:
            print(json.dumps(r))
        checks = claims(out)
    n_ok = 0
    for name, ok, detail in checks:
        print(f"[{'PASS' if ok else 'FAIL'}] {name} ({detail})")
        n_ok += bool(ok)
    if n_ok < len(checks):
        sys.exit(1)

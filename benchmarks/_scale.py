"""Shared smoke/fast/full scale selection for benchmark modules.

``benchmarks/run.py --smoke`` exports REPRO_BENCH_SMOKE (and
REPRO_BENCH_FAST); REPRO_BENCH_FAST alone is the interactive quick
pass.  Modules resolve their horizon through :func:`bench_duration` so
adding a mode (or renaming an env var) is a one-file change.
"""

from __future__ import annotations

import os


def bench_mode() -> str:
    """The active scale: ``"smoke"``, ``"fast"`` or ``"full"``."""
    if os.environ.get("REPRO_BENCH_SMOKE"):
        return "smoke"
    if os.environ.get("REPRO_BENCH_FAST"):
        return "fast"
    return "full"


def bench_duration(duration, smoke: float, fast: float, full: float) -> float:
    """Resolve a ``run()`` horizon: an explicit argument wins, otherwise
    the per-mode default."""
    if duration:
        return duration
    return {"smoke": smoke, "fast": fast, "full": full}[bench_mode()]


def bench_adaptive(flag=None) -> bool:
    """Whether campaign benchmarks should use the sequential adaptive
    sampler (``repro.core.sampling``) instead of the fixed seed grid.
    An explicit ``run(adaptive=...)`` argument wins; otherwise
    ``REPRO_BENCH_ADAPTIVE`` (set by ``benchmarks/run.py --adaptive``)."""
    if flag is not None:
        return bool(flag)
    return bool(os.environ.get("REPRO_BENCH_ADAPTIVE"))


def run_campaign(camp, adaptive: bool, baseline: str = "terastal"):
    """Execute ``camp`` and return a ``CampaignResult`` — through the
    fixed grid (``Campaign.run``) or, when ``adaptive``, through the
    sequential sampler with the given baseline scheduler, printing the
    per-campaign trial savings so adaptive benchmark logs show what the
    early stopping bought."""
    if not adaptive:
        return camp.run()
    from repro.core.sampling import SamplerConfig, run_adaptive

    res = run_adaptive(camp, SamplerConfig(baseline=baseline))
    early = sum(v.reason != "cap" for v in res.verdicts)
    print(
        f"[adaptive] {'/'.join(camp.scenarios)}: {res.n_trials}/"
        f"{res.n_trials_cap} trials ({100 * res.trials_saved():.0f}% saved; "
        f"{early}/{len(res.verdicts)} comparisons stopped early)",
        flush=True,
    )
    return res.campaign_result()

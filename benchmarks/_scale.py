"""Shared smoke/fast/full scale selection for benchmark modules.

``benchmarks/run.py --smoke`` exports REPRO_BENCH_SMOKE (and
REPRO_BENCH_FAST); REPRO_BENCH_FAST alone is the interactive quick
pass.  Modules resolve their horizon through :func:`bench_duration` so
adding a mode (or renaming an env var) is a one-file change.
"""

from __future__ import annotations

import os


def bench_mode() -> str:
    """The active scale: ``"smoke"``, ``"fast"`` or ``"full"``."""
    if os.environ.get("REPRO_BENCH_SMOKE"):
        return "smoke"
    if os.environ.get("REPRO_BENCH_FAST"):
        return "fast"
    return "full"


def bench_duration(duration, smoke: float, fast: float, full: float) -> float:
    """Resolve a ``run()`` horizon: an explicit argument wins, otherwise
    the per-mode default."""
    if duration:
        return duration
    return {"smoke": smoke, "fast": fast, "full": full}[bench_mode()]

"""Fig. 7 (beyond-paper): miss rate vs arrival burstiness.

The paper evaluates strictly periodic releases only.  Real multi-tenant
traffic is bursty (DREAM's serving traces, MMPP arrival models from the
real-time literature), so this campaign sweeps the arrival process from
periodic through Poisson to increasingly bursty MMPP for each
conventional baseline and Terastal, with bootstrap confidence intervals
over seeds — showing where Terastal's advantage widens or collapses as
arrivals deviate from the periodic assumption its virtual budgets are
calibrated for.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core import Campaign

# Burstiness ladder: x-axis position -> arrival call-spec.  Periodic sits
# at 0 (no variance), Poisson at 1 (CV=1), MMPP above (CV grows with the
# ON-rate multiple).
ARRIVAL_LADDER = (
    (0.0, "periodic"),
    (1.0, "poisson"),
    (2.0, "mmpp(burstiness=2)"),
    (4.0, "mmpp(burstiness=4)"),
    (8.0, "mmpp(burstiness=8)"),
)

SCHEDULERS = ("fcfs", "edf", "dream", "terastal")

# Two representative cells: one AR (variant-rich) and one multi-camera
# (throughput-bound) scenario on their paper-paired platforms.
CELLS = (
    ("ar_gaming_heavy", "6k_1ws2os"),
    ("multicam_light", "4k_1ws2os"),
)


def run(duration: float = None, seeds=tuple(range(8)), adaptive: bool = None) -> List[dict]:
    from benchmarks._scale import bench_adaptive, bench_duration, bench_mode, run_campaign

    mode = bench_mode()
    adaptive = bench_adaptive(adaptive)
    duration = bench_duration(duration, smoke=0.4, fast=1.0, full=3.0)
    if mode == "smoke":
        seeds = (0, 1)  # >= 2: aggregate()'s CIs refuse degenerate samples
    elif mode == "fast":
        seeds = (0, 1, 2)
    burst_of = {spec: b for b, spec in ARRIVAL_LADDER}
    rows: List[dict] = []
    for sc, pn in CELLS:
        camp = Campaign(
            scenarios=(sc,),
            platforms=(pn,),
            schedulers=SCHEDULERS,
            arrivals=tuple(spec for _, spec in ARRIVAL_LADDER),
            seeds=tuple(seeds),
            duration=duration,
        )
        result = run_campaign(camp, adaptive)
        for agg in result.aggregate(by=("scenario", "platform", "scheduler", "arrival")):
            rows.append({
                "scenario": agg["scenario"],
                "platform": agg["platform"],
                "scheduler": agg["scheduler"],
                "arrival": agg["arrival"],
                "burstiness": burst_of[agg["arrival"]],
                "miss_rate_pct": 100 * agg["mean_miss_rate"],
                "ci_lo_pct": 100 * agg["mean_miss_rate_ci_lo"],
                "ci_hi_pct": 100 * agg["mean_miss_rate_ci_hi"],
                "n_trials": agg["n_trials"],
            })
    return rows


def claims(rows: List[dict]):
    by_sched: Dict[str, List[float]] = {}
    for r in rows:
        by_sched.setdefault(r["scheduler"], []).append(r["miss_rate_pct"])
    mean = {k: float(np.mean(v)) for k, v in by_sched.items()}

    n_expected = len(CELLS) * len(SCHEDULERS) * len(ARRIVAL_LADDER)
    ci_sane = all(r["ci_lo_pct"] - 1e-9 <= r["miss_rate_pct"] <= r["ci_hi_pct"] + 1e-9 for r in rows)

    def at(burst: float) -> List[dict]:
        return [r for r in rows if r["burstiness"] == burst]

    # burstiness stresses the system: miss averaged over schedulers rises
    # from the periodic baseline to the burstiest MMPP level
    base = float(np.mean([r["miss_rate_pct"] for r in at(0.0)]))
    worst = float(np.mean([r["miss_rate_pct"] for r in at(8.0)]))

    # terastal stays ahead of every baseline per burstiness level (its
    # layer-wise slack reasoning is not an artifact of periodic arrivals)
    ahead_per_level = all(
        float(np.mean([r["miss_rate_pct"] for r in at(b) if r["scheduler"] == "terastal"]))
        <= float(np.mean([r["miss_rate_pct"] for r in at(b) if r["scheduler"] == s])) + 1e-9
        for b, _ in ARRIVAL_LADDER
        for s in ("fcfs", "edf", "dream")
    )

    return [
        ("full (cell x scheduler x arrival) grid covered with sane CIs",
         len(rows) == n_expected and ci_sane, f"{len(rows)}/{n_expected} rows"),
        ("burstier arrivals raise the average miss rate",
         worst > base, f"periodic {base:.2f}% -> mmpp(8) {worst:.2f}%"),
        ("terastal beats fcfs/edf/dream averaged over the ladder",
         all(mean["terastal"] < mean[s] for s in ("fcfs", "edf", "dream")),
         f"terastal {mean['terastal']:.2f}% vs " + ", ".join(f"{s} {mean[s]:.2f}%" for s in ("fcfs", "edf", "dream"))),
        ("terastal no worse than every baseline at every burstiness level",
         ahead_per_level, "per-level means compared"),
    ]

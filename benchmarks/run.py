"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (micro section) plus a
per-figure results table and a claim-validation summary.  Set
REPRO_BENCH_FAST=1 for a quick pass, or run with ``--smoke`` (CI): a
tiny grid / 1 seed / short horizon per benchmark, claim results printed
but informational — the smoke pass exists so every registered benchmark
script is executed end-to-end and cannot silently rot.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

# `python benchmarks/run.py` puts benchmarks/ (not the repo root) on
# sys.path; make the `benchmarks` package importable regardless of cwd.
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)


def _section(title: str) -> None:
    print(f"\n## {title}", flush=True)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="tiny grid, 1 seed, short horizon; claim failures do not fail "
        "the run (statistics are meaningless at smoke scale) — only "
        "crashes do",
    )
    ap.add_argument(
        "--adaptive",
        action="store_true",
        help="run the campaign figures (fig5/fig7/fig8) through the "
        "sequential adaptive sampler (stop cells when the CIs separate) "
        "instead of the fixed seed grid",
    )
    args = ap.parse_args(argv)
    if args.smoke:
        # set before the benchmark modules read them at run() time
        os.environ["REPRO_BENCH_FAST"] = "1"
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    if args.adaptive:
        os.environ["REPRO_BENCH_ADAPTIVE"] = "1"

    from benchmarks import (
        ablation_backfill,
        bench_batch_trials,
        bench_campaign_throughput,
        bench_lm_serving,
        bench_micro,
        bench_sampler_efficiency,
        bench_scheduler_round,
        fig3_vgg11_latency,
        fig4_accuracy_vs_variants,
        fig5_miss_rate,
        fig6_threshold_sweep,
        fig7_arrival_robustness,
        fig8_adaptive_budgets,
        fig9_overload_control,
        fig10_fault_tolerance,
        fig11_dag_workloads,
        fig12_fault_budgets,
        table_storage,
    )

    all_claims = []
    t0 = time.time()

    _section("micro (name,us_per_call,derived)")
    micro = bench_micro.run()
    for r in micro:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    all_claims += [("bench_micro", *c) for c in bench_micro.claims(micro)]

    for mod, title in [
        (fig3_vgg11_latency, "fig3: VGG11 per-layer WS/OS latency + variants"),
        (fig4_accuracy_vs_variants, "fig4: accuracy vs #variants"),
        (fig5_miss_rate, "fig5: deadline miss rates (headline)"),
        (fig6_threshold_sweep, "fig6: accuracy-threshold sweep"),
        (fig7_arrival_robustness, "fig7: miss rate vs arrival burstiness (campaign)"),
        (fig8_adaptive_budgets, "fig8: online budget policies under burstiness"),
        (fig9_overload_control,
         "fig9: overload control — admission/shedding + closed-loop clients "
         "(writes BENCH_overload.json)"),
        (fig10_fault_tolerance,
         "fig10: fault tolerance — accelerator faults + variant-based "
         "graceful degradation (writes BENCH_faults.json)"),
        (fig11_dag_workloads,
         "fig11: DAG-structured workloads — layer-precedence scheduling "
         "(writes BENCH_dag.json)"),
        (fig12_fault_budgets,
         "fig12: fault-aware budget re-tightening + degraded-capacity "
         "admission (writes BENCH_fault_budgets.json)"),
        (table_storage, "storage overhead"),
        (ablation_backfill, "ablation: stage-2 backfill guard interpretations"),
        (bench_lm_serving, "beyond-paper: LM serving on mesh partitions"),
        (bench_campaign_throughput,
         "perf: SoA vs reference engine trials/sec (writes BENCH_campaign.json)"),
        (bench_sampler_efficiency,
         "perf: adaptive sampler trials saved at matched verdicts "
         "(writes BENCH_sampler.json)"),
        (bench_scheduler_round,
         "perf: deep-queue round kernels, rounds/sec vs NJ "
         "(writes BENCH_round.json)"),
        (bench_batch_trials,
         "perf: device-resident mega-batched trials vs the campaign path "
         "(writes BENCH_batch.json)"),
    ]:
        _section(title)
        rows = mod.run()
        for r in rows:
            print(json.dumps(r))
        all_claims += [(mod.__name__.split(".")[-1], *c) for c in mod.claims(rows)]

    _section("claim validation")
    n_ok = 0
    for src, claim, ok, detail in all_claims:
        status = "PASS" if ok else "FAIL"
        n_ok += bool(ok)
        print(f"[{status}] {src}: {claim} ({detail})")
    print(f"\n{n_ok}/{len(all_claims)} claims validated in {time.time()-t0:.0f}s")
    if n_ok < len(all_claims):
        if args.smoke:
            print("(smoke mode: claims informational at this scale; not failing)")
        else:
            sys.exit(1)


if __name__ == "__main__":
    main()

"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (micro section) plus a
per-figure results table and a claim-validation summary.  Set
REPRO_BENCH_FAST=1 for a quick pass.
"""

from __future__ import annotations

import json
import sys
import time


def _section(title: str) -> None:
    print(f"\n## {title}", flush=True)


def main() -> None:
    from benchmarks import (
        ablation_backfill,
        bench_lm_serving,
        bench_micro,
        fig3_vgg11_latency,
        fig4_accuracy_vs_variants,
        fig5_miss_rate,
        fig6_threshold_sweep,
        fig7_arrival_robustness,
        table_storage,
    )

    all_claims = []
    t0 = time.time()

    _section("micro (name,us_per_call,derived)")
    micro = bench_micro.run()
    for r in micro:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    all_claims += [("bench_micro", *c) for c in bench_micro.claims(micro)]

    for mod, title in [
        (fig3_vgg11_latency, "fig3: VGG11 per-layer WS/OS latency + variants"),
        (fig4_accuracy_vs_variants, "fig4: accuracy vs #variants"),
        (fig5_miss_rate, "fig5: deadline miss rates (headline)"),
        (fig6_threshold_sweep, "fig6: accuracy-threshold sweep"),
        (fig7_arrival_robustness, "fig7: miss rate vs arrival burstiness (campaign)"),
        (table_storage, "storage overhead"),
        (ablation_backfill, "ablation: stage-2 backfill guard interpretations"),
        (bench_lm_serving, "beyond-paper: LM serving on mesh partitions"),
    ]:
        _section(title)
        rows = mod.run()
        for r in rows:
            print(json.dumps(r))
        all_claims += [(mod.__name__.split(".")[-1], *c) for c in mod.claims(rows)]

    _section("claim validation")
    n_ok = 0
    for src, claim, ok, detail in all_claims:
        status = "PASS" if ok else "FAIL"
        n_ok += bool(ok)
        print(f"[{status}] {src}: {claim} ({detail})")
    print(f"\n{n_ok}/{len(all_claims)} claims validated in {time.time()-t0:.0f}s")
    if n_ok < len(all_claims):
        sys.exit(1)


if __name__ == "__main__":
    main()
